"""Golden regression: a fixed seeded simulation pinned to its summary
stats, so simulator refactors cannot silently drift latency accounting.

The scenario: h2o-danube-3-4b on a 128x128 array (numpy-built cost table
— float64, backend-deterministic), a seeded lognormal Poisson trace, both
admission policies, and a finite-UB variant. Any intentional change to
the event loop, the interpolation, or the spill accounting must
regenerate the fixture AND say why in the commit.

Regenerate with (from the repo root):
    PYTHONPATH=src:tests python -c "
import json, test_traffic_golden as g
json.dump(g.golden_records(), open(g.FIXTURE, 'w'),
          indent=1, sort_keys=True)"
"""
import functools
import json
import os

import pytest

from repro.traffic import (SLO, SimConfig, TrafficModel, build_cost_tables,
                           simulate, summarize)

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "traffic_sim_golden.json")

ARCH = "h2o-danube-3-4b"
N_REQUESTS = 2500
SEED = 1234
PINNED = ("ttft_p50_s", "ttft_p99_s", "tpot_p50_s", "tpot_p99_s",
          "tokens_per_sec", "energy_per_token", "goodput_qps",
          "goodput_frac", "spill_frac_of_decode", "sim_seconds",
          "completed", "tokens_out")

CASES = {
    "prefill_first": SimConfig(slots=16),
    "chunked": SimConfig(slots=16, policy="chunked", chunk=128),
    "prefill_first_tight_ub": SimConfig(slots=16, ub_kib=24 * 1024.0),
}


def _trace():
    # ~60% of the design point's saturation rate: loaded enough that
    # queueing and batching effects show, stable enough that the stats
    # mean something
    return TrafficModel(rate_qps=1.5, prompt_median=256,
                        prompt_range=(16, 2048), output_median=48,
                        output_range=(1, 512)).sample(N_REQUESTS, SEED)


@functools.lru_cache(maxsize=None)
def _table():
    return build_cost_tables(
        archs=[ARCH], hw=((128, 128),), backend="numpy"
    ).table(ARCH, 128, 128)


def golden_records():
    tab = _table()
    tr = _trace()
    slo = SLO(ttft_s=5.0, tpot_s=0.2)
    out = {}
    for name, cfg in CASES.items():
        summ = summarize(simulate(tab, tr, cfg), slo)
        out[name] = {k: summ[k] for k in PINNED}
    return out


with open(FIXTURE) as f:
    GOLDEN = json.load(f)


def test_fixture_covers_all_cases():
    assert set(GOLDEN) == set(CASES)
    for rec in GOLDEN.values():
        assert set(rec) == set(PINNED)


@pytest.mark.parametrize("case", sorted(CASES))
def test_seeded_simulation_matches_golden(case):
    got = golden_records()[case]
    want = GOLDEN[case]
    for k in PINNED:
        assert got[k] == pytest.approx(want[k], rel=1e-9, abs=1e-12), (
            f"{case}/{k}: simulator output drifted vs the pinned fixture "
            "(if intentional, regenerate tests/fixtures/traffic_sim_golden"
            ".json — see module docstring)")
