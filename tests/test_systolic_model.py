"""Analytical model vs wavefront emulator: instruction-exact agreement,
plus hypothesis property tests on the model's invariants."""
import numpy as np
import jax.numpy as jnp
import pytest
from _hyp import given, settings, st

from repro.core.emulator import emulate_gemm
from repro.core.systolic import analyze_gemm, analyze_network

CASES = [(7, 13, 9, 5, 4), (12, 16, 16, 8, 8), (3, 5, 21, 4, 6),
         (10, 8, 8, 8, 8), (5, 17, 3, 16, 8), (1, 100, 10, 16, 16),
         (33, 7, 50, 3, 11), (2, 2, 2, 2, 2)]


@pytest.mark.parametrize("M,K,N,h,w", CASES)
def test_emulator_numeric_matches_matmul(M, K, N, h, w):
    rng = np.random.default_rng(0)
    A = rng.normal(size=(M, K)).astype(np.float32)
    W = rng.normal(size=(K, N)).astype(np.float32)
    O, _ = emulate_gemm(jnp.asarray(A), jnp.asarray(W), h, w)
    np.testing.assert_allclose(np.asarray(O), A @ W, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,K,N,h,w", CASES)
def test_analytical_matches_emulator_exactly(M, K, N, h, w):
    rng = np.random.default_rng(1)
    A = rng.normal(size=(M, K)).astype(np.float32)
    W = rng.normal(size=(K, N)).astype(np.float32)
    _, c = emulate_gemm(jnp.asarray(A), jnp.asarray(W), h, w)
    m = analyze_gemm(M, K, N, h, w, count_weight_load_hops=True)
    assert c["cycles"] == float(m.cycles) - float(m.weight_load_cycles)
    assert c["first_load"] + c["exposed"] == float(m.weight_load_cycles)
    assert c["macs"] == float(m.macs)
    assert c["aa"] == float(m.m_aa)
    assert (c["inter_act"] + c["inter_psum"] + c["wload"]
            == float(m.m_inter_pe))
    assert c["ub_act_reads"] == float(m.m_ub_act)
    assert c["ub_weight_reads"] == float(m.m_ub_weight)
    assert c["ub_out_writes"] == float(m.m_ub_out)


@settings(max_examples=60, deadline=None)
@given(M=st.integers(1, 64), K=st.integers(1, 96), N=st.integers(1, 96),
       h=st.integers(1, 48), w=st.integers(1, 48))
def test_model_invariants(M, K, N, h, w):
    m = analyze_gemm(M, K, N, h, w)
    assert 0 < float(m.utilization) <= 1.0 + 1e-9
    # cycle lower bounds: streaming M rows per tile + skew
    Tk, Tn = -(-K // h), -(-N // w)
    assert float(m.cycles) >= Tk * Tn * M
    assert float(m.macs) == M * K * N
    # perfect-fit arrays reach the streaming bound
    if K % h == 0 and N % w == 0:
        assert float(m.cycles) == Tk * Tn * (M + h + w - 1) + h
    # energy monotone in workload
    m2 = analyze_gemm(M + 1, K, N, h, w)
    assert float(m2.energy) > float(m.energy)
    assert float(m2.cycles) > float(m.cycles)


@settings(max_examples=40, deadline=None)
@given(M=st.integers(1, 32), K=st.integers(2, 64), N=st.integers(2, 64),
       h=st.integers(2, 32), w=st.integers(2, 32),
       g=st.integers(1, 4))
def test_group_serialization(M, K, N, h, w, g):
    """g groups == g serialized GEMMs (paper's grouping semantics)."""
    one = analyze_gemm(M, K, N, h, w)
    grp = analyze_gemm(M, K, N, h, w, groups=g)
    assert float(grp.cycles) == g * float(one.cycles)
    assert float(grp.energy) == g * float(one.energy)


def test_utilization_pow2_effect():
    """Full tiles (pow2 operands on pow2 arrays) beat misaligned ones."""
    aligned = analyze_gemm(256, 512, 512, 128, 128)
    misaligned = analyze_gemm(256, 520, 520, 128, 128)
    assert float(aligned.utilization) > float(misaligned.utilization)


def test_network_combination():
    wls = [(16, 32, 32, 1, 2), (8, 64, 16, 4, 1)]
    tot = analyze_network(wls, 16, 16)
    parts = [analyze_gemm(16, 32, 32, 16, 16, groups=2),
             analyze_gemm(8, 64, 16, 16, 16, groups=4)]
    assert float(tot.cycles) == sum(float(p.cycles) for p in parts)
    assert float(tot.energy) == sum(float(p.energy) for p in parts)
