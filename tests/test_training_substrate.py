"""Optimizer, checkpoint/restart, fault tolerance, straggler, data
pipeline, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as CKPT
from repro.configs.base import ShapeConfig, get_config, reduced
from repro.data.pipeline import DataConfig, TokenPipeline, batch_at
from repro.launch.steps import (abstract_train_state, init_train_state,
                                make_train_step)
from repro.models.model_zoo import build_model
from repro.training import optimizer as OPT
from repro.training.train_loop import LoopConfig, StragglerMonitor, run


def _tiny_setup(tmp, arch="yi-9b", accum=1):
    cfg = reduced(get_config(arch))
    b = build_model(cfg)
    ocfg = OPT.OptConfig(lr=5e-3, warmup_steps=5, total_steps=200,
                         accum_steps=accum)
    state = init_train_state(b, ocfg, jax.random.key(0))
    step = jax.jit(make_train_step(b, ocfg, None))
    shape = ShapeConfig("t", 64, 2, "train")
    data = TokenPipeline(DataConfig(seed=3), cfg, shape)
    return b, state, step, data, cfg


@pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="seed-known-failing on jax without the jax.shard_map API "
           "(pre-0.6 pins; see CHANGES.md)")
def test_loss_decreases(tmp_path):
    _, state, step, data, _ = _tiny_setup(tmp_path)
    losses = []
    for _ in range(30):
        state, m = step(state, next(data))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses[::6]


def test_quantized_moments_track_fp32():
    cfg = OPT.OptConfig(lr=1e-2)
    cfg_q = OPT.OptConfig(lr=1e-2, quant_moments=True)
    p = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(4, 32, 64)),
                          jnp.float32)}
    s, sq = OPT.init_state(cfg, p), OPT.init_state(cfg_q, p)
    pq = dict(p)
    for i in range(5):
        g = jax.tree.map(
            lambda x: 0.01 * jnp.asarray(
                np.random.default_rng(i).normal(size=x.shape), x.dtype), p)
        p, s, _ = OPT.apply_updates(cfg, p, g, s)
        pq, sq, _ = OPT.apply_updates(cfg_q, pq, g, sq)
    diff = float(jnp.max(jnp.abs(p["w"] - pq["w"])))
    scale = float(jnp.max(jnp.abs(p["w"])))
    assert diff < 0.05 * scale


def test_grad_accumulation_matches_full_batch(tmp_path):
    b, state, step1, data, cfg = _tiny_setup(tmp_path, accum=1)
    _, state2, step2, _, _ = _tiny_setup(tmp_path, accum=2)
    batch = next(data)
    s1, m1 = step1(state, batch)
    s2, m2 = step2(state2, batch)
    # same initial params => same grads => same updated params (within fp)
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(a - b_))),
                     s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_checkpoint_atomic_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.asarray(7, jnp.int32)}}
    CKPT.save(str(tmp_path), 5, tree)
    CKPT.save(str(tmp_path), 10, jax.tree.map(lambda x: x + 1, tree))
    assert CKPT.latest_step(str(tmp_path)) == 10
    got, step = CKPT.restore(str(tmp_path), tree)
    assert step == 10
    np.testing.assert_allclose(np.asarray(got["a"]),
                               np.asarray(tree["a"]) + 1)


def test_crash_restart_resumes_identically(tmp_path):
    ck = str(tmp_path / "ck")
    lcfg = LoopConfig(total_steps=12, ckpt_every=4, ckpt_dir=ck,
                      async_ckpt=False)
    _, state0, step, data, cfg = _tiny_setup(tmp_path)
    # uninterrupted run
    sA, histA = run(step, state0, data, lcfg, resume=False)
    # crashed run: same init, fails at step 9 then resumes from step 8
    import shutil
    shutil.rmtree(ck, ignore_errors=True)
    _, state0b, stepb, datab, _ = _tiny_setup(tmp_path)
    with pytest.raises(RuntimeError):
        run(stepb, state0b, datab, lcfg, resume=False, crash_at=9)
    _, state0c, stepc, datac, _ = _tiny_setup(tmp_path)
    sB, histB = run(stepc, state0c, datac, lcfg, resume=True)
    assert histB["resumed_from"] == 8
    np.testing.assert_allclose(histA["loss"][8:], histB["loss"],
                               rtol=1e-4, atol=1e-5)


def test_async_checkpointer(tmp_path):
    ac = CKPT.AsyncCheckpointer(str(tmp_path))
    tree = {"x": jnp.ones((64, 64))}
    ac.save(1, tree)
    ac.save(2, jax.tree.map(lambda a: a * 2, tree))   # waits for save 1
    ac.wait()
    got, step = CKPT.restore(str(tmp_path), tree)
    assert step == 2
    np.testing.assert_allclose(np.asarray(got["x"]), 2.0)


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(window=8, threshold=3.0)
    for i in range(10):
        mon.observe(i, 0.1)
    assert mon.observe(10, 0.5)
    assert mon.events and mon.events[0]["step"] == 10
    assert not mon.observe(11, 0.12)


def test_data_pipeline_deterministic_and_elastic():
    d = DataConfig(seed=9, vocab_size=128)
    b1 = batch_at(d, 7, 4, 16)
    b2 = batch_at(d, 7, 4, 16)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    # restartability: iterating to step 7 equals direct addressing
    cfg = reduced(get_config("yi-9b"))
    pipe = TokenPipeline(d, cfg, ShapeConfig("t", 16, 4, "train"),
                         start_step=7)
    b3 = next(pipe)
    d2 = DataConfig(seed=9, vocab_size=cfg.vocab_size)
    np.testing.assert_array_equal(
        np.asarray(b3["tokens"]),
        np.asarray(batch_at(d2, 7, 4, 16)["tokens"]))


def test_serving_engine_drains():
    from repro.serving.engine import ServingEngine, Request
    cfg = reduced(get_config("yi-9b"))
    b = build_model(cfg)
    params = jax.tree.map(lambda a: a.astype(jnp.bfloat16),
                          b.init_params(jax.random.key(0)))
    eng = ServingEngine(b, params, slots=2, cache_len=64)
    rng = np.random.default_rng(0)
    for rid in range(3):
        eng.submit(Request(rid, rng.integers(0, 64, size=8,
                                             dtype=np.int32), max_new=4))
    eng.run_to_completion(max_ticks=64)
    assert all(r is None for r in eng.active)
