"""Windowed telemetry + SLO burn-rate monitoring (obs/windowed.py) and
the scheduled non-stationary traffic it watches (traffic.RateSchedule).

Two golden fixtures ride along:

  * ``schedule_golden.json`` — the seeded arrival stream (and tenant
    assignment) of a diurnal + burst RateSchedule, pinned at 1e-9, so
    the inversion sampler cannot silently drift;
  * ``windowed_alerts_golden.json`` — the full alert sequence fired by
    the canonical seeded burst replay, the determinism contract the CI
    windowed gate enforces.

Regenerate (from the repo root, only with a commit saying why):
    PYTHONPATH=src:tests python -c "
import json, test_windowed as g
json.dump(g.schedule_records(), open(g.SCHEDULE_FIXTURE, 'w'),
          indent=1, sort_keys=True)
json.dump(g.burst_alert_records(), open(g.ALERTS_FIXTURE, 'w'),
          indent=1, sort_keys=True)"
"""
import functools
import json
import math
import os

import numpy as np
import pytest

from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.windowed import (BurnRateRule, SLOMonitor, WindowConfig,
                                WindowedAggregator, default_burn_rules,
                                localize_breach, worst_window_goodput)
from repro.traffic import (SLO, SimConfig, TrafficModel, build_cost_tables,
                           simulate, summarize)
from repro.traffic.workload import RateSchedule

FIXDIR = os.path.join(os.path.dirname(__file__), "fixtures")
SCHEDULE_FIXTURE = os.path.join(FIXDIR, "schedule_golden.json")
ALERTS_FIXTURE = os.path.join(FIXDIR, "windowed_alerts_golden.json")

ARCH = "h2o-danube-3-4b"


@functools.lru_cache(maxsize=None)
def _table():
    return build_cost_tables(
        archs=[ARCH], hw=((128, 128),), backend="numpy"
    ).table(ARCH, 128, 128)


# ------------------------------------------------------------- schedules --

SCHED = RateSchedule(base_qps=8.0, diurnal_amplitude=0.5,
                     diurnal_period_s=240.0, diurnal_phase_s=30.0,
                     segments=((300.0, 1.5),),
                     bursts=((20.0, 15.0, 3.0),))


def schedule_records():
    tm = TrafficModel(arrival="scheduled", schedule=SCHED, rate_qps=8.0,
                      tenant_probs=(0.6, 0.3, 0.1),
                      tenant_names=("interactive", "batch", "bulk"))
    tr = tm.sample(400, seed=99)
    return {"arrival_s": [float(x) for x in tr.arrival_s],
            "tenant_id": [int(x) for x in tr.tenant_id]}


with open(SCHEDULE_FIXTURE) as f:
    SCHEDULE_GOLDEN = json.load(f)


def test_schedule_sampling_matches_golden():
    got = schedule_records()
    assert got["tenant_id"] == SCHEDULE_GOLDEN["tenant_id"]
    want = SCHEDULE_GOLDEN["arrival_s"]
    assert len(got["arrival_s"]) == len(want)
    for g, w in zip(got["arrival_s"], want):
        assert g == pytest.approx(w, rel=1e-9, abs=1e-12), (
            "scheduled arrival stream drifted vs the pinned fixture "
            "(if intentional, regenerate tests/fixtures/schedule_golden"
            ".json — see module docstring)")


def test_schedule_rate_shape():
    t = np.linspace(0.0, 600.0, 2001)
    r = SCHED.rate(t)
    assert np.all(r > 0.0)
    # burst overlay multiplies inside [20, 35) only
    base = RateSchedule(base_qps=8.0, diurnal_amplitude=0.5,
                        diurnal_period_s=240.0, diurnal_phase_s=30.0,
                        segments=((300.0, 1.5),)).rate(t)
    inside = (t >= 20.0) & (t < 35.0)
    assert np.allclose(r[inside], 3.0 * base[inside])
    assert np.allclose(r[~inside], base[~inside])
    # segment multiplies from its start onward (t=310: no burst there)
    assert np.allclose(SCHED.rate(np.array([310.0]))[0],
                       1.5 * 8.0 * (1.0 + 0.5 * math.sin(
                           2.0 * math.pi * (310.0 - 30.0) / 240.0)))


def test_schedule_scaled_preserves_shape():
    t = np.linspace(0.0, 500.0, 997)
    ratio = SCHED.scaled(2.5).rate(t) / SCHED.rate(t)
    assert np.allclose(ratio, 2.5)


def test_scheduled_arrivals_deterministic_and_monotone():
    a1 = SCHED.arrivals(500, np.random.default_rng([5, 0]))
    a2 = SCHED.arrivals(500, np.random.default_rng([5, 0]))
    assert np.array_equal(a1, a2)
    assert np.all(np.diff(a1) > 0.0)
    # more arrivals land where the rate is high: the 3x burst span
    # [20, 35) outpaces the same-width calm opening [0, 15)
    burst = ((a1 >= 20.0) & (a1 < 35.0)).sum()
    calm = (a1 < 15.0).sum()
    assert burst > calm


def test_with_rate_rescales_schedule_and_bisection_moves():
    from repro.traffic.slo import QPS_CAP, max_sustainable_qps
    tm = TrafficModel(arrival="scheduled", schedule=SCHED, rate_qps=8.0)
    tm2 = tm.with_rate(2.0)
    assert tm2.schedule.base_qps == 2.0
    # shape preserved: every other schedule field untouched
    assert tm2.schedule.bursts == SCHED.bursts
    assert tm2.schedule.diurnal_amplitude == SCHED.diurnal_amplitude
    # offered rate actually moves with the dial
    n = 3000
    h1 = tm.with_rate(4.0).sample(n, seed=1).arrival_s[-1]
    h2 = tm.with_rate(8.0).sample(n, seed=1).arrival_s[-1]
    assert h1 > 1.5 * h2
    # regression: the SLO capacity bisection must MOVE on scheduled
    # traffic (a with_rate that didn't rescale the schedule would make
    # every probe identical and the bisection meaningless)
    q, summ = max_sustainable_qps(
        _table(), tm, SLO(ttft_s=5.0, tpot_s=0.25),
        sim=SimConfig(slots=16), n_requests=300, seed=0)
    assert 0.0 < q < QPS_CAP
    # the dial sets the BASE rate; the burst/segment multipliers push the
    # realized offered rate above it, never below
    assert summ["offered_qps"] > q
    assert summ["meets_slo"]


def test_tenant_stream_seeded_and_independent():
    tm = TrafficModel(rate_qps=2.0, tenant_probs=(0.5, 0.5))
    t1 = tm.sample(500, seed=3)
    t2 = tm.sample(500, seed=3)
    assert np.array_equal(t1.tenant_id, t2.tenant_id)
    # the tenant axis draws from its own child stream: arrivals/lengths
    # are byte-identical with the axis on or off
    t0 = TrafficModel(rate_qps=2.0).sample(500, seed=3)
    assert np.array_equal(t0.arrival_s, t1.arrival_s)
    assert np.array_equal(t0.prompt_len, t1.prompt_len)
    assert t0.tenant_id is None


# ----------------------------------------------- histogram satellites --

def test_quantile_interp_property_vs_numpy():
    rng = np.random.default_rng(42)
    for scale in (0.05, 1.0, 20.0):
        x = rng.lognormal(math.log(scale), 0.7, 4000)
        h = Histogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
        h.observe_many(x)
        ratio = 10.0 ** (1.0 / 4.0)           # bucket edge ratio
        prev = -np.inf
        for q in (0.05, 0.25, 0.5, 0.75, 0.9, 0.99):
            est = h.quantile(q, interp=True)
            ref = float(np.percentile(x, 100.0 * q))
            # within one bucket of the true quantile
            assert ref / ratio <= est <= ref * ratio, (q, est, ref)
            # interpolated estimate never above the bucket upper edge
            assert est <= h.quantile(q) + 1e-12
            assert est >= prev                 # monotone in q
            prev = est


def test_quantile_default_unchanged_and_edges():
    h = Histogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
    h.observe_many([0.5] * 100)
    # default: upper bucket edge, strictly above the sample
    assert h.quantile(0.5) >= 0.5
    assert h.quantile(0.5) == h.quantile(0.5, interp=False)
    # underflow/overflow interpolate against the observed extremes
    h2 = Histogram(lo=1.0, hi=10.0, buckets_per_decade=1)
    h2.observe_many([0.25, 0.5, 20.0, 40.0])
    assert 0.25 <= h2.quantile(0.2, interp=True) <= 1.0
    assert 10.0 <= h2.quantile(0.99, interp=True) <= 40.0
    assert math.isnan(Histogram().quantile(0.5, interp=True))


def test_registry_conflicting_bounds_raise():
    reg = MetricsRegistry()
    reg.observe("lat", 0.1, lo=1e-4, hi=1e2)
    reg.observe("lat", 0.2)                    # defaults = no opinion: OK
    reg.observe("lat", 0.3, lo=1e-4)           # matching explicit: OK
    with pytest.raises(ValueError, match="conflicting"):
        reg.observe("lat", 0.4, lo=1e-3)
    with pytest.raises(ValueError, match="conflicting"):
        reg.hist("lat", hi=1e3)
    with pytest.raises(ValueError, match="conflicting"):
        reg.hist("lat", buckets_per_decade=8)
    assert reg.histograms["lat"].n == 3


# --------------------------------------------------- window aggregation --

def _sim_windowed(wcfg, **cfg_kw):
    tm = TrafficModel(arrival="scheduled", schedule=SCHED, rate_qps=8.0,
                      tenant_probs=(0.7, 0.3))
    trace = tm.sample(2000, seed=11)
    res = simulate(_table(), trace,
                   SimConfig(slots=16, windows=wcfg, **cfg_kw))
    return trace, res


def test_windowed_off_by_default():
    tm = TrafficModel(rate_qps=1.5)
    res = simulate(_table(), tm.sample(200, seed=0), SimConfig(slots=16))
    assert res.windowed is None


def test_windows_do_not_change_the_replay():
    tm = TrafficModel(rate_qps=1.5)
    tr = tm.sample(400, seed=2)
    r0 = simulate(_table(), tr, SimConfig(slots=16))
    r1 = simulate(_table(), tr,
                  SimConfig(slots=16, windows=WindowConfig(window_s=5.0)))
    assert np.array_equal(r0.ttft_s, r1.ttft_s, equal_nan=True)
    assert np.array_equal(r0.tpot_s, r1.tpot_s, equal_nan=True)
    assert r0.energy_eq1 == r1.energy_eq1
    assert r0.sim_seconds == r1.sim_seconds
    assert r0.decode_steps == r1.decode_steps


def test_merged_window_histograms_reproduce_whole_run_exactly():
    wcfg = WindowConfig(window_s=10.0, slide_s=2.5)
    trace, res = _sim_windowed(wcfg)
    s = res.windowed
    done = np.isfinite(res.tpot_s)
    for kind, vals in (("ttft", res.ttft_s[done]),
                       ("tpot", res.tpot_s[done])):
        whole = Histogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
        whole.observe_many(vals)
        merged = s.merged_histogram(kind)
        assert merged.counts == whole.counts      # EXACT integer equality
        assert merged.n == whole.n
    # and against the summarize() records the capacity answers carry
    rec = summarize(res, None)
    assert s.merged_histogram("ttft").to_dict()["counts"] \
        == rec["ttft_hist"]["counts"]
    assert s.merged_histogram("tpot").to_dict()["counts"] \
        == rec["tpot_hist"]["counts"]


def test_windowed_conservation_against_sim_totals():
    wcfg = WindowConfig(window_s=10.0)
    trace, res = _sim_windowed(wcfg, breakdown=True)
    s = res.windowed
    done = np.isfinite(res.tpot_s)
    assert int(s.arrivals.sum()) == res.n
    assert int(s.completions.sum()) == int(done.sum())
    assert s.tokens.sum() == pytest.approx(res.tokens_out, abs=1e-6)
    assert s.busy_s.sum() == pytest.approx(
        res.prefill_seconds + res.decode_seconds, rel=1e-9)
    assert s.spill_s.sum() == pytest.approx(res.spill_seconds, abs=1e-9)
    assert s.energy.sum() == pytest.approx(res.energy_eq1, rel=1e-9)
    assert s.decode_steps.sum() == pytest.approx(res.decode_steps,
                                                 rel=1e-9)
    # exact decode-slot-seconds integral == total decode-phase seconds
    dec = (res.tpot_s * trace.output_len)[done].sum()
    assert s.active_slot_s.sum() == pytest.approx(dec, rel=1e-9)
    # attribution parts conserve against the per-request decompositions
    expect = res.ttft_parts[done].sum() + res.tpot_parts[done].sum()
    assert sum(v.sum() for v in s.parts.values()) == pytest.approx(
        expect, rel=1e-9)
    # tenants partition the counts
    assert sum(c["arrivals"].sum() for c in s.tenants.values()) == res.n
    assert sum(c["completions"].sum() for c in s.tenants.values()) \
        == int(done.sum())


def test_sliding_windows_roll_buckets():
    wcfg = WindowConfig(window_s=20.0, slide_s=5.0)
    _, res = _sim_windowed(wcfg)
    s = res.windowed
    assert s.cfg.buckets_per_window == 4
    assert s.n_windows == max(s.n_buckets - 3, 1)
    arr = s._roll(s.arrivals)
    for w in range(min(5, s.n_windows)):
        assert arr[w] == s.arrivals[w:w + 4].sum()
    # window edges slide at the bucket stride
    assert np.allclose(np.diff(s.window_starts), 5.0)
    rows = s.records()
    assert len(rows) == s.n_windows
    assert rows[1]["t0_s"] - rows[0]["t0_s"] == pytest.approx(5.0)


def test_window_config_validation():
    with pytest.raises(ValueError):
        WindowConfig(window_s=0.0)
    with pytest.raises(ValueError):
        WindowConfig(window_s=10.0, slide_s=3.0)      # not a divisor
    with pytest.raises(ValueError):
        WindowConfig(window_s=10.0, slide_s=20.0)     # > window
    with pytest.raises(ValueError):
        WindowConfig(slo_ttft_s=1.0)                  # targets come paired
    with pytest.raises(ValueError):
        BurnRateRule("r", long_s=10.0, short_s=20.0, max_burn_rate=2.0)
    with pytest.raises(ValueError):
        SLOMonitor(budget=0.0)


# ------------------------------------------------------- SLO monitoring --

def _synthetic_series(bad_buckets, B=40, per_bucket=100, window_s=30.0):
    """A hand-built series: `per_bucket` completions per bucket, 100% bad
    inside `bad_buckets`, perfect elsewhere."""
    cfg = WindowConfig(window_s=window_s, slo_ttft_s=1.0, slo_tpot_s=0.1)
    agg = WindowedAggregator(cfg)
    b = cfg.bucket_s
    arrival = np.repeat(np.arange(B) * b + 0.5 * b, per_bucket)
    ttft = np.full(B * per_bucket, 0.01)
    for k in bad_buckets:
        ttft[k * per_bucket:(k + 1) * per_bucket] = 5.0   # SLO-violating
    tpot = np.full(B * per_bucket, 0.001)
    olen = np.ones(B * per_bucket)
    agg.ingest_requests(arrival, ttft, tpot, olen)
    return agg.finalize(t_end=B * b)


def test_monitor_state_machine_and_budget():
    s = _synthetic_series(bad_buckets=(10, 11, 12))
    mon = SLOMonitor(budget=0.01)
    res = mon.evaluate(s)
    seq = [(a.rule, a.state) for a in res.alerts]
    assert ("fast_burn", "pending") in seq
    assert ("fast_burn", "firing") in seq
    assert ("fast_burn", "resolved") in seq
    assert res.fired
    # alert times are non-decreasing (the Perfetto contract)
    ts = [a.t for a in res.alerts]
    assert ts == sorted(ts)
    # budget: 3 of 40 buckets fully bad = 7.5% bad, 7.5x the 1% budget
    assert res.final_budget_consumed == pytest.approx(7.5)
    # a clean series fires nothing and burns nothing
    clean = _synthetic_series(bad_buckets=())
    r0 = SLOMonitor(budget=0.01).evaluate(clean)
    assert not r0.alerts and r0.final_budget_consumed == 0.0


def test_monitor_for_s_holds_pending():
    s = _synthetic_series(bad_buckets=(10,))
    rule = BurnRateRule("slow_trigger", long_s=60.0, short_s=30.0,
                        max_burn_rate=2.0, for_s=1e9)
    res = SLOMonitor(budget=0.01, rules=[rule]).evaluate(s)
    states = {a.state for a in res.alerts}
    assert "pending" in states and "firing" not in states
    assert not res.fired


def test_monitor_requires_slo_targets():
    wcfg = WindowConfig(window_s=10.0)
    _, res = _sim_windowed(wcfg)
    with pytest.raises(ValueError, match="slo"):
        SLOMonitor().evaluate(res.windowed)


def test_default_burn_rules_scale_with_window():
    fast, slow = default_burn_rules(60.0)
    assert fast.long_s == 240.0 and fast.short_s == 60.0
    assert slow.severity == "ticket" and fast.severity == "page"


# ----------------------------------------------- canonical burst replay --

def _burst_replay():
    sched = RateSchedule(base_qps=1.5, bursts=((120.0, 40.0, 2.5),))
    tm = TrafficModel(arrival="scheduled", schedule=sched, rate_qps=1.5,
                      prompt_median=256, prompt_range=(16, 2048),
                      output_median=48, output_range=(1, 512))
    trace = tm.sample(1500, seed=7)
    wcfg = WindowConfig(window_s=30.0, slo_ttft_s=2.0, slo_tpot_s=0.2)
    res = simulate(_table(), trace, SimConfig(slots=16, windows=wcfg))
    return res, SLOMonitor(budget=0.02).evaluate(res.windowed)


def burst_alert_records():
    _, mon = _burst_replay()
    return {"alerts": [a.to_dict() for a in mon.alerts],
            "final_budget_consumed": mon.final_budget_consumed}


with open(ALERTS_FIXTURE) as f:
    ALERTS_GOLDEN = json.load(f)


def test_burst_replay_alert_sequence_matches_golden():
    got = burst_alert_records()
    want = ALERTS_GOLDEN
    assert len(got["alerts"]) == len(want["alerts"])
    for g, w in zip(got["alerts"], want["alerts"]):
        assert g["rule"] == w["rule"] and g["state"] == w["state"]
        for k in ("t", "burn_long", "burn_short"):
            assert g[k] == pytest.approx(w[k], rel=1e-9, abs=1e-12), (
                f"alert {k} drifted vs tests/fixtures/windowed_alerts_"
                "golden.json (regenerate only with a commit saying why)")
    assert got["final_budget_consumed"] == pytest.approx(
        want["final_budget_consumed"], rel=1e-9)
    # the canonical sequence tells the whole story: both rules fire and
    # both eventually resolve
    states = [(a["rule"], a["state"]) for a in got["alerts"]]
    for rule in ("fast_burn", "slow_burn"):
        assert (rule, "firing") in states
        assert (rule, "resolved") in states


def test_monitor_emit_validates_in_perfetto_export():
    from repro.obs import Tracer, to_trace_events, trace_json, \
        validate_trace
    res, mon = _burst_replay()
    tr = Tracer(clock="sim")
    mon.emit(tr, track="slo")
    events = to_trace_events(tr)
    assert validate_trace(events) == []
    # burn-rate counter tracks + alert instants are all present
    names = {e["name"] for e in events}
    assert "burn_rate" in names and "error_budget" in names
    assert "slo_alert_firing" in names and "slo_alert_resolved" in names
    # byte-identical export on a second evaluate+emit
    tr2 = Tracer(clock="sim")
    _burst_replay()[1].emit(tr2, track="slo")
    assert trace_json(tr) == trace_json(tr2)


# ------------------------------------------------------- fleet rollups --

def test_fleet_windowed_rollup_and_localization():
    from repro.fleet.sim import FleetSimConfig, FleetTables, simulate_fleet
    tabs = build_cost_tables(archs=[ARCH], hw=((128, 128), (96, 96)),
                             backend="numpy")
    fleet = FleetTables(mixed=[tabs.table(ARCH, 128, 128),
                               tabs.table(ARCH, 96, 96)])
    sched = RateSchedule(base_qps=3.0, bursts=((60.0, 30.0, 3.0),))
    tm = TrafficModel(arrival="scheduled", schedule=sched, rate_qps=3.0,
                      tenant_probs=(0.8, 0.2))
    trace = tm.sample(1200, seed=5)
    wcfg = WindowConfig(window_s=20.0, slo_ttft_s=2.0, slo_tpot_s=0.2)
    fr = simulate_fleet(fleet, trace,
                        FleetSimConfig(server=SimConfig(slots=16,
                                                        windows=wcfg)))
    s = fr.windowed
    done = np.isfinite(fr.tpot_s)
    assert int(s.arrivals.sum()) == fr.n
    assert int(s.completions.sum()) == int(done.sum())
    # absorbed per-server engine series conserve against the fleet sums
    assert s.busy_s.sum() == pytest.approx(
        fr.prefill_seconds + fr.decode_seconds, rel=1e-9)
    assert s.energy.sum() == pytest.approx(fr.energy_eq1, rel=1e-9)
    assert s.slots == 32
    # fleet-level merged histogram == fleet-level whole-run histogram
    whole = Histogram()
    whole.observe_many(fr.ttft_s[np.isfinite(fr.ttft_s)])
    assert s.merged_histogram("ttft").counts == whole.counts
    # per-server series feed breach localization
    sw = fr.server_windowed
    assert set(sw) == {"server0", "server1"}
    rank = localize_breach(sw, t=fr.sim_seconds, span_s=fr.sim_seconds)
    assert len(rank) == 2 and rank[0][1] >= rank[1][1]
    # windows off => no series anywhere
    fr0 = simulate_fleet(fleet, trace,
                         FleetSimConfig(server=SimConfig(slots=16)))
    assert fr0.windowed is None and fr0.server_windowed == {}


def test_worst_window_goodput_finds_the_burst():
    wcfg = WindowConfig(window_s=30.0, slo_ttft_s=2.0, slo_tpot_s=0.2)
    res, _ = _burst_replay()
    ww = worst_window_goodput(res.windowed)
    assert ww["good_frac"] < 0.5
    # the worst window overlaps the burst-driven backlog, not the calm
    # opening minutes
    assert ww["t0_s"] >= 90.0


def test_dse_windowed_scoring_hook():
    from repro.core.dse import slo_capacity_sweep
    sched = RateSchedule(base_qps=1.5, bursts=((120.0, 40.0, 2.5),))
    tm = TrafficModel(arrival="scheduled", schedule=sched, rate_qps=1.5)
    sw = slo_capacity_sweep(
        tm, SLO(ttft_s=2.0, tpot_s=0.25), archs=[ARCH], hw=[(128, 128)],
        backend="numpy", n_requests=400, seed=0,
        windows=WindowConfig(window_s=10.0))
    wd = sw.summaries[0][0]["windowed"]
    assert wd is not None
    for k in ("worst_window_goodput_qps", "burn_alerts_fired",
              "budget_consumed", "peak_burn_flagged", "day_average_ok"):
        assert k in wd
    # deterministic: the same sweep annotates identically
    sw2 = slo_capacity_sweep(
        tm, SLO(ttft_s=2.0, tpot_s=0.25), archs=[ARCH], hw=[(128, 128)],
        backend="numpy", n_requests=400, seed=0,
        windows=WindowConfig(window_s=10.0))
    assert sw2.summaries[0][0]["windowed"] == wd


def test_windowed_report_renders_deterministically():
    from repro.obs.report import windowed_report
    res, mon = _burst_replay()
    r1 = windowed_report(res.windowed, mon)
    res2, mon2 = _burst_replay()
    assert windowed_report(res2.windowed, mon2) == r1
    assert "| t0_s |" in r1 and "## SLO burn" in r1
    assert "fast_burn" in r1
