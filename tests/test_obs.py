"""Observability layer: tracer semantics, metrics registry, Perfetto
export validity/determinism, and the dispatch-count invariants that turn
PR 3/4 docstring claims ("ONE fused dispatch", "O(events) not O(tokens)",
"zero model evals in the replay loop") into regression tests."""
import functools
import json

import numpy as np
import pytest

from repro import obs
from repro.fleet.sim import FleetSimConfig, FleetTables, simulate_fleet
from repro.traffic.cost_table import build_cost_tables
from repro.traffic.sim import SimConfig, simulate
from repro.traffic.slo import SLO, summarize
from repro.traffic.workload import TrafficModel

ARCH = "h2o-danube-3-4b"
SLOTS = (1, 2, 4, 8)
KVS = (64, 128, 256, 512)
PROMPTS = (16, 64, 256, 1024)


@functools.lru_cache(maxsize=None)
def _tables():
    return build_cost_tables(archs=[ARCH], hw=((64, 64), (128, 128)),
                             slot_lattice=SLOTS, kv_lattice=KVS,
                             prompt_lattice=PROMPTS, backend="numpy",
                             block_c=2)


def _trace(n=300, qps=40.0, seed=0):
    return TrafficModel(rate_qps=qps, prompt_median=128,
                        output_median=16).sample(n, seed=seed)


# ------------------------------------------------------------- tracer API --

def test_tracer_span_nesting_and_balance():
    tr = obs.Tracer(clock="wall")
    with tr.span("outer", "t"):
        with tr.span("inner", "t", depth=1):
            pass
    assert [ev[obs.trace.PH] for ev in tr.events] == ["B", "B", "E", "E"]
    assert tr.open_spans() == {}
    # E pairs LIFO with the innermost B's name
    assert tr.events[2][obs.trace.NAME] == "inner"
    assert tr.events[3][obs.trace.NAME] == "outer"


def test_tracer_end_without_begin_raises():
    tr = obs.Tracer(clock="wall")
    with pytest.raises(RuntimeError):
        tr.end("t")


def test_sim_clock_requires_explicit_ts():
    tr = obs.Tracer(clock="sim")
    with pytest.raises(ValueError):
        tr.begin("x", "t")              # no ts on a sim-clock tracer
    tr.begin("x", "t", ts=1.0)
    tr.end("t", ts=2.0)
    assert len(tr) == 2


def test_disabled_tracer_records_nothing():
    tr = obs.Tracer(enabled=False, clock="sim")
    tr.begin("x", "t", ts=0.0)
    tr.complete("y", "t", 0.0, 1.0)
    tr.instant("z", "t", ts=0.5)
    tr.counter("c", "t", ts=0.5, v=1)
    tr.async_begin("r", "t", 0, 0.0)
    with tr.span("s", "t"):
        pass
    assert len(tr) == 0 and tr.open_spans() == {}


def test_tracks_first_appearance_order():
    tr = obs.Tracer(clock="sim")
    tr.instant("a", "z", ts=0.0)
    tr.instant("b", "a", ts=1.0)
    tr.instant("c", "z", ts=2.0)
    assert tr.tracks() == ["z", "a"]


# -------------------------------------------------------------- histogram --

def test_histogram_counts_and_quantiles():
    h = obs.Histogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
    vals = [1e-4, 0.002, 0.02, 0.2, 2.0, 20.0, 2e4]
    for v in vals:
        h.observe(v)
    assert h.n == len(vals) == sum(h.counts)
    assert h.counts[0] == 1 and h.counts[-1] == 1   # under/overflow
    assert h.vmin == 1e-4 and h.vmax == 2e4
    q50 = h.quantile(0.5)
    assert 0.02 <= q50 <= 2.0
    json.dumps(h.to_dict())                         # JSON-ready


def test_histogram_observe_many_matches_loop():
    rng = np.random.default_rng(0)
    vals = rng.lognormal(-2.0, 2.0, 5000)
    h1 = obs.Histogram()
    h2 = obs.Histogram()
    for v in vals:
        h1.observe(v)
    h2.observe_many(vals)
    assert h1.counts == h2.counts and h1.n == h2.n
    assert h1.total == pytest.approx(h2.total)


def test_histogram_observe_many_drops_non_finite():
    h = obs.Histogram()
    h.observe_many([1.0, np.nan, np.inf, 2.0])
    assert h.n == 2


# --------------------------------------------------------------- registry --

def test_registry_inc_add_many_delta():
    reg = obs.MetricsRegistry()
    reg.inc("a")
    reg.inc("a", 2)
    before = reg.snapshot()
    reg.add_many({"a": 1, "b": 5})
    assert reg.get("a") == 4 and reg.get("b") == 5
    assert reg.delta(before) == {"a": 1, "b": 5}
    reg.observe("lat", 0.5)
    s = reg.summarize()
    assert s["counters"]["a"] == 4 and s["histograms"]["lat"]["n"] == 1
    json.loads(reg.to_json())


# ----------------------------------------------------------------- export --

def test_validate_catches_unbalanced_and_nonmonotone():
    evs = [{"ph": "B", "name": "x", "pid": 1, "tid": 1, "ts": 2.0},
           {"ph": "I", "name": "y", "pid": 1, "tid": 1, "ts": 1.0}]
    probs = obs.validate_trace(evs)
    assert any("ts" in p for p in probs)            # non-monotone
    assert any("unbalanced" in p for p in probs)    # open B
    evs = [{"ph": "e", "name": "r", "pid": 1, "tid": 1, "ts": 0.0,
            "cat": "req", "id": "0"}]
    assert any("async end" in p for p in obs.validate_trace(evs))


def test_traced_replay_exports_valid_trace():
    tab = _tables().table(ARCH, 128, 128)
    tr = obs.Tracer(clock="sim")
    res = simulate(tab, _trace(), SimConfig(slots=8, tracer=tr,
                                            track="server0"))
    assert np.isfinite(res.tpot_s).all()
    assert len(tr) > 0 and tr.open_spans() == {}
    events = obs.to_trace_events(tr)
    assert obs.validate_trace(events) == []
    # every phase the lifecycle promises is present
    names = {e["name"] for e in events}
    assert {"request", "first_token", "queue", "decode"} <= names


def test_seeded_disagg_fleet_trace_byte_identical_and_per_server_tracks():
    """Acceptance bar: >= 2 servers, disagg enabled, valid trace, one
    track per server/pool, byte-identical across two seeded runs."""
    ts = _tables()
    fleet = FleetTables(prefill=[ts.table(ARCH, 64, 64)],
                        decode=[ts.table(ARCH, 64, 64),
                                ts.table(ARCH, 128, 128)])
    blobs = []
    for _ in range(2):
        tr = obs.Tracer(clock="sim")
        cfg = FleetSimConfig(routing="round_robin",
                             server=SimConfig(slots=8, tracer=tr))
        res = simulate_fleet(fleet, _trace(), cfg)
        assert res.disaggregated and res.n_servers == 3
        tracks = set(tr.tracks())
        assert {"prefill0", "kv_link", "decode0", "decode1"} <= tracks
        assert obs.validate_trace(obs.to_trace_events(tr)) == []
        blobs.append(obs.trace_json(tr))
        # per-server bounded timelines ride along on the result
        tls = res.server_timelines
        assert len(tls) == 2 and all(t.shape[1] == 3 for t in tls)
    assert blobs[0] == blobs[1]


def test_untraced_fleet_configs_stay_equal():
    """No tracer => per-server configs are the shared cfg.server object
    (SimConfig equality is what lets the batched search pack lanes)."""
    cfg = FleetSimConfig(server=SimConfig(slots=8))
    from repro.fleet.sim import _server_cfg
    assert _server_cfg(cfg, "server", 1) is cfg.server


# ------------------------------------------------- dispatch-count claims --

def test_scenario_sweep_is_one_fused_dispatch():
    from repro.core import get_workloads
    from repro.core.dse import scenario_sweep
    named = {"a": get_workloads("alexnet")[:3],
             "b": get_workloads("resnet152")[:3]}
    before = obs.metrics().snapshot()
    scenario_sweep(named, hs=(16, 32), ws=(16, 32), backend="pallas",
                   fused=True, block_c=2)
    d = obs.metrics().delta(before)
    assert d.get("kernels.fused_dispatches") == 1
    assert "kernels.sweep_dispatches" not in d


def test_build_stage_tables_is_one_fused_dispatch():
    from repro.fleet.partition import build_stage_tables
    before = obs.metrics().snapshot()
    build_stage_tables([ARCH], hw=((64, 64),), tps=(1,), backend="pallas",
                       block_c=2, slot_lattice=SLOTS[:2],
                       kv_lattice=KVS[:2], prompt_lattice=PROMPTS[:2])
    d = obs.metrics().delta(before)
    assert d.get("kernels.fused_dispatches") == 1


def test_replay_loop_does_zero_model_evals_and_is_o_events():
    tab = _tables().table(ARCH, 128, 128)
    trace = _trace(n=500, qps=60.0)
    before = obs.metrics().snapshot()
    res = simulate(tab, trace, SimConfig(slots=8))
    d = obs.metrics().delta(before)
    assert "model.network_evals" not in d          # zero evals in the loop
    assert "model.gemm_evals" not in d
    assert d["sim.replays"] == 1 and d["sim.requests"] == 500
    # O(events): loop iterations are a small multiple of requests, far
    # below the token count a step-per-token simulator would pay
    assert d["sim.events"] < 6 * 500
    assert res.tokens_out > d["sim.events"]
    assert d["sim.decode_steps"] == res.decode_steps
    assert d["sim.table_lookups"] > 0


def test_bisection_probe_counter():
    from repro.traffic.slo import max_sustainable_qps
    tab = _tables().table(ARCH, 128, 128)
    tm = TrafficModel(rate_qps=10.0, prompt_median=64, output_median=8)
    before = obs.metrics().snapshot()
    max_sustainable_qps(tab, tm, SLO(ttft_s=5.0, tpot_s=1.0),
                        SimConfig(slots=8), n_requests=100, iters=3)
    d = obs.metrics().delta(before)
    assert d.get("slo.bisection_probes", 0) >= 4   # bracket + 3 bisections


# --------------------------------------------------- timeline decimation --

def test_timeline_decimation_keeps_tail_and_bound():
    tab = _tables().table(ARCH, 128, 128)
    trace = _trace(n=2000, qps=100.0, seed=1)
    full = simulate(tab, trace, SimConfig(slots=8,
                                          timeline_samples=1 << 20))
    dec = simulate(tab, trace, SimConfig(slots=8, timeline_samples=8))
    assert len(full.timeline) > 2 * 8      # halving actually triggered
    assert len(dec.timeline) <= 2 * 8
    t_dec, t_full = dec.timeline[:, 0], full.timeline[:, 0]
    assert (np.diff(t_dec) > 0).all()
    assert set(t_dec) <= set(t_full)       # decimation only drops samples
    # the tail survives: the newest retained sample sits in the last
    # stretch of the replay, not half a trace ago
    assert t_dec[-1] >= 0.9 * t_full[-1]


# --------------------------------------------------- summarize histograms --

def test_summarize_carries_latency_histograms():
    tab = _tables().table(ARCH, 128, 128)
    res = simulate(tab, _trace(), SimConfig(slots=8))
    out = summarize(res, SLO(ttft_s=2.0, tpot_s=0.5))
    for key in ("ttft_hist", "tpot_hist"):
        h = out[key]
        assert h["n"] == out["completed"] == sum(h["counts"])
        json.dumps(h)
    # bucket CDF agrees with the percentile within bucket resolution
    hq = obs.Histogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
    hq.observe_many(res.ttft_s)
    q99 = hq.quantile(0.99)
    edge = 10.0 ** (1.0 / 4)               # one log-bucket of slack
    assert q99 / edge <= max(out["ttft_p99_s"], 1e-3) * edge * edge


# -------------------------------------------------- wall spans in the DSE --

def test_dse_sweep_emits_wall_spans():
    from repro.core.dse import slo_capacity_sweep
    tm = TrafficModel(rate_qps=10.0, prompt_median=64, output_median=8)
    old = obs.set_tracer(obs.Tracer(enabled=True, clock="wall"))
    try:
        slo_capacity_sweep(tm, SLO(ttft_s=5.0, tpot_s=1.0), archs=[ARCH],
                           hw=((64, 64),), tables=_tables(),
                           sim=SimConfig(slots=4), n_requests=60, seed=0)
        tr = obs.tracer()
        names = [ev[obs.trace.NAME] for ev in tr.events]
        assert "capacity_search" in names
        assert "lockstep_round" in names   # search="auto" -> batched path
        assert tr.open_spans() == {}
        assert obs.validate_trace(obs.to_trace_events(tr)) == []
    finally:
        obs.set_tracer(old)
