"""Backend-equivalence: every DSE sweep entry point must agree between
`backend="numpy"` (float64 closed forms) and `backend="pallas"` (the fused
sweep kernel, f32 in interpret mode off-TPU) to <= 1e-6 normalized error —
they are the SAME closed forms (core/model_core.py), so any drift is a
backend bug, not model disagreement."""
import numpy as np
import pytest

from repro.core import capacity_sweep, equal_pe_sweep, get_workloads
from repro.core.dse import grid_axes
from repro.graph import build_graph

SMALL = grid_axes()[::5]
TOL = 1e-6

METRICS = ("cycles", "energy", "utilization", "m_ub", "m_inter_pe",
           "m_aa", "ub_bw_bits")


def _max_rel(a, b):
    a = np.asarray(a, np.float64)
    b = np.asarray(b, np.float64)
    return float((np.abs(a - b) / (np.abs(a) + 1.0)).max())


@pytest.mark.parametrize("name", ["alexnet", "resnet152",
                                  "mobilenetv3_large"])
def test_capacity_sweep_backends_agree_to_1e6(name):
    """(h, w, ub_kib) space: the closed-form base grid AND the spill-
    augmented totals agree across backends; the liveness/spill terms are
    backend-independent by construction (computed once on the graph)."""
    cs_np = capacity_sweep(build_graph(name), hs=SMALL, ws=SMALL,
                           backend="numpy")
    cs_pl = capacity_sweep(build_graph(name), hs=SMALL, ws=SMALL,
                           backend="pallas")
    for k in METRICS:
        err = _max_rel(getattr(cs_np.base, k), getattr(cs_pl.base, k))
        assert err <= TOL, (name, k, err)
    assert _max_rel(cs_np.energy_total, cs_pl.energy_total) <= TOL
    np.testing.assert_array_equal(cs_np.spill_bits, cs_pl.spill_bits)
    assert cs_np.peak_bits == cs_pl.peak_bits


@pytest.mark.parametrize("total_pes", [1024, 4096])
def test_equal_pe_sweep_backends_agree_to_1e6(total_pes):
    """Fig. 6 aspect-ratio sweep at constant PE count: numpy vs the fused
    kernel path, including the extreme-ratio ends of the sweep."""
    mw = {n: get_workloads(n) for n in ("alexnet", "resnet152")}
    a = equal_pe_sweep(mw, total_pes=total_pes)
    b = equal_pe_sweep(mw, total_pes=total_pes, backend="pallas")
    for name in mw:
        np.testing.assert_array_equal(a[name]["h"], b[name]["h"])
        np.testing.assert_array_equal(a[name]["w"], b[name]["w"])
        for k in ("energy", "cycles", "utilization"):
            err = _max_rel(a[name][k], b[name][k])
            assert err <= TOL, (name, k, err)


@pytest.mark.parametrize("model_kw", [{}, {"act_reread": True},
                                      {"idle_pe_energy": 0.1}])
def test_equal_pe_sweep_backends_agree_with_model_options(model_kw):
    """Model options must thread through both equal-PE backends alike."""
    mw = {"alexnet": get_workloads("alexnet")}
    a = equal_pe_sweep(mw, total_pes=1024, **model_kw)
    b = equal_pe_sweep(mw, total_pes=1024, backend="pallas", **model_kw)
    for k in ("energy", "cycles", "utilization"):
        assert _max_rel(a["alexnet"][k], b["alexnet"][k]) <= TOL, k
